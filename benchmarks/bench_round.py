"""Table 2 reproduction: federation round time (secs) for the 10M-param model
across federation sizes, MetisFL-arm vs naive-arm — plus the dispatch-scaling
arm (``--dispatch``) and the wire-aware semi-sync sizing arm (``--schedule``).

Paper Table 2 (10M params): MetisFL 4.58/6.10/14.13/21.28/45.61 s for
10/25/50/100/200 learners vs e.g. IBM FL 175->1915 s.  Our two arms
reproduce the *shape* of that comparison on this host; EXPERIMENTS.md
compares the scaling exponents.

``--dispatch`` measures the serialize-once broadcast claim: per-round train
*dispatch* wall time must stay ~flat in federation size N (the global model
is serialized once per round and fanned out as shared envelopes — O(P + N)),
against the legacy per-send arm that re-serializes per learner (O(N·P)).
Defaults follow the acceptance shape: N ∈ {8, 32, 128} at P = 2^23 (≥ 2^22).

``--schedule`` measures the wire-cost-aware semi-sync sizing claim: under a
bandwidth cap, the hyper-period budget must cover *train + round-trip wire*
time.  The naive arm (``wire_aware=False``) sizes tasks from train time only
and overshoots the hyper-period by roughly the wire time; the wire-aware arm
(default) subtracts each learner's modeled round-trip (broadcast down +
upload payload up, ``Controller.wire_time_s``) and stays within budget.
"""

from __future__ import annotations

import argparse
import json
import time


def run(learner_counts=(10, 25, 50), size="10m", include_naive=True):
    from benchmarks.bench_ops import _metis_round, _naive_round

    rows = []
    for n in learner_counts:
        m = _metis_round(size, n)
        rows.append({"bench": "round", "size": size, "learners": n,
                     "arm": "metis", "federation_round_s": m["federation_round_s"]})
        print(f"round,metis,{size},{n},{m['federation_round_s']:.3f}s", flush=True)
        if include_naive:
            nv = _naive_round(size, n)
            rows.append({"bench": "round", "size": size, "learners": n,
                         "arm": "naive",
                         "federation_round_s": nv["federation_round_s"]})
            print(f"round,naive,{size},{n},{nv['federation_round_s']:.3f}s",
                  flush=True)
    return rows


# ---------------------------------------------------------------------------
# dispatch-scaling arm
# ---------------------------------------------------------------------------


def _make_null_learner(lid, upload_buffer):
    """A learner that trains instantly and uploads a pre-packed flat buffer.

    Isolates the *dispatch* path: the round still runs the full engine
    machinery (broadcast, recv, UploadArrived ingest + arena write,
    aggregation, eval fan-out) but no local SGD, so ``train_dispatch_s`` is
    measured under realistic envelope traffic without minutes of training
    per round.
    """
    from repro.core import EvalReport, Learner, LocalUpdate
    from repro.optim import sgd

    class _NullLearner(Learner):
        def fit(self, params, task):
            return LocalUpdate(
                learner_id=self.learner_id, round_id=task.round_id,
                params=None, num_examples=1, metrics={}, seconds_per_step=0.0,
                buffer=upload_buffer,
            )

        def evaluate(self, params, round_id):
            return EvalReport(self.learner_id, round_id,
                              {"eval_loss": 0.0}, 1)

    dummy = lambda *a, **k: None  # noqa: E731 - never called by _NullLearner
    return _NullLearner(lid, dummy, dummy, dummy, dummy, sgd(0.1), 1)


def run_dispatch(learner_counts=(8, 32, 128), p=1 << 23, rounds=3,
                 include_persend=True):
    """Per-round train-dispatch wall time vs federation size N.

    The wire cache is invalidated before every measured round (as if the
    model had just been re-published), so each dispatch pays its one
    serialization inside the timed region — the worst case; in steady state
    that single serialization is shared with the previous round's eval
    fan-out.  Median over ``rounds`` engine rounds: the completion side
    (N recvs + N arena writes) runs concurrently with the next
    measurement's setup and adds noise on small hosts.  The ``persend`` arm
    is the legacy cost: one full serialization per learner.
    """
    import jax.numpy as jnp

    from repro.core import Channel, Controller, SyncProtocol

    rows = []
    base = None
    for n in learner_counts:
        ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=1),
                          arena_n_max=n)
        params = {"w": jnp.zeros((p,), jnp.float32)}
        ctrl.set_initial_model(params)
        upload = jnp.zeros((ctrl.arena.padded_params,), jnp.float32)
        for i in range(n):
            ctrl.register_learner(_make_null_learner(f"l{i}", upload))

        def one_dispatch():
            ctrl.invalidate_wire_cache()  # model re-published: cold cache
            return ctrl.engine.run(rounds=1)[0].train_dispatch_s

        one_dispatch()  # warmup: compiles recv/arena-write programs
        dispatch = sorted(one_dispatch() for _ in range(rounds))
        dispatch_s = dispatch[len(dispatch) // 2]
        serialized = ctrl.telemetry.value("channel.serializations")
        assert ctrl.telemetry.value("controller.upload_fallback_packs") == 0, \
            "flat upload path not engaged"
        ctrl.shutdown()

        persend_s = None
        if include_persend:
            ch = Channel()
            t0 = time.perf_counter()
            for _ in range(n):
                ch.send(params)
            persend_s = time.perf_counter() - t0

        row = {"bench": "dispatch", "params": p, "learners": n,
               "dispatch_s": dispatch_s, "persend_s": persend_s,
               "serializations_total": serialized}
        if base is None:
            base = dispatch_s
        row["ratio_vs_smallest_n"] = dispatch_s / base
        rows.append(row)
        persend_txt = f",persend={persend_s*1e3:.1f}ms" if persend_s else ""
        print(f"dispatch,P={p},N={n},dispatch={dispatch_s*1e3:.2f}ms"
              f"{persend_txt},ratio={row['ratio_vs_smallest_n']:.2f}x",
              flush=True)
    flat = rows[-1]["dispatch_s"] / rows[0]["dispatch_s"]
    note = ("<=1.5x expected at this payload: serialize-once"
            if p >= 1 << 22 else
            "smoke payload: fan-out overhead dominates; the <=1.5x "
            "flatness claim holds at P>=2^22")
    print(f"dispatch flatness: {flat:.2f}x from N={learner_counts[0]} to "
          f"N={learner_counts[-1]} ({note})", flush=True)
    return rows


# ---------------------------------------------------------------------------
# flight-recorder overhead arm
# ---------------------------------------------------------------------------


def run_journal(p=1 << 20, n=8, rounds=12):
    """Flight-recorder overhead: journaled rounds vs recording disabled.

    Two identical null-learner federations run the same engine rounds; the
    baseline disables recording entirely (``journal_capacity=0`` — the
    ``record()`` early-exit), the journal arm keeps the default ring *and*
    streams JSONL to a file sink (the worst case: serialization work plus a
    background flusher competing for the GIL).  Reported overhead is the
    median per-round delta; the acceptance target is < 2%.  The journal
    arm's row also embeds the run's telemetry snapshot and journal/replay
    accounting — the artifact shape the nightly CI archives.
    """
    import os
    import tempfile

    import jax.numpy as jnp

    from repro.core import Controller, SyncProtocol

    def build(journal_capacity, journal_sink):
        ctrl = Controller(protocol=SyncProtocol(local_steps=1, batch_size=1),
                          arena_n_max=n, journal_capacity=journal_capacity,
                          journal_sink=journal_sink)
        ctrl.set_initial_model({"w": jnp.zeros((p,), jnp.float32)})
        upload = jnp.zeros((ctrl.arena.padded_params,), jnp.float32)
        for i in range(n):
            ctrl.register_learner(_make_null_learner(f"l{i}", upload))
        return ctrl

    def median_round_s(ctrl):
        ctrl.engine.run(rounds=2)  # warmup: compiles recv/arena-write/agg
        t = sorted(r.federation_round_s for r in ctrl.engine.run(rounds=rounds))
        return t[len(t) // 2]

    with tempfile.TemporaryDirectory() as tmp:
        base = build(0, None)
        base_s = median_round_s(base)
        assert len(base.journal.records()) == 0, "baseline journal not disabled"
        base.shutdown()

        sink = os.path.join(tmp, "journal.jsonl")
        ctrl = build(4096, sink)
        journal_s = median_round_s(ctrl)
        snapshot = ctrl.telemetry.snapshot()
        summaries = ctrl.journal.replay()
        cursor = ctrl.journal.cursor
        ctrl.shutdown()
        sink_records = len(ctrl.journal.read_jsonl(sink))

    overhead_pct = 100.0 * (journal_s - base_s) / max(base_s, 1e-12)
    row = {"bench": "journal", "params": p, "learners": n, "rounds": rounds,
           "baseline_round_s": base_s, "journal_round_s": journal_s,
           "overhead_pct": overhead_pct,
           "journal_records": cursor, "sink_records": sink_records,
           "rounds_replayed": len([s for s in summaries if s.aggregated]),
           "telemetry": snapshot}
    print(f"journal,P={p},N={n},base={base_s*1e3:.2f}ms,"
          f"journaled={journal_s*1e3:.2f}ms,overhead={overhead_pct:+.2f}%,"
          f"records={cursor},sink={sink_records}", flush=True)
    assert sink_records == cursor, "flush-on-stop lost records"
    return [row]


# ---------------------------------------------------------------------------
# fault-injecting stress arm
# ---------------------------------------------------------------------------


def run_stress_arm(learners=1000, rounds=5, fault_seed=7, protocols=None):
    """Thousand-learner churn sweep: every protocol under injected faults.

    Drives ``tests/stress/harness.run_stress`` — a SimLearner fleet on the
    real engine/transport/journal with seeded dropout/rejoin churn, upload
    loss + duplication, heavy-tailed stragglers, and per-learner bandwidth
    caps — once per protocol, and reports uploads/sec, rounds/sec, the
    staleness histogram, and every ``engine.faults.*`` counter as JSON
    rows.  The same ``--fault-seed`` reproduces the identical run
    (byte-identical journal JSONL; ``tests/stress/test_stress.py`` pins
    that contract on small fleets).
    """
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tests"))
    from stress.harness import STRESS_PROTOCOLS, run_stress

    from repro.core import FaultSpec

    spec = FaultSpec(
        seed=fault_seed, dropout_rate=0.05, rejoin_rate=0.5,
        upload_loss_rate=0.02, upload_dup_rate=0.02, straggler_rate=0.1,
        bandwidth_min_gbps=0.05, bandwidth_max_gbps=10.0,
    )
    rows = []
    for name in (protocols or STRESS_PROTOCOLS):
        row = run_stress(protocol=name, learners=learners, rounds=rounds,
                         spec=spec)
        row["bench"] = "stress"
        rows.append(row)
        f = row["faults"]
        print(f"stress,{name},N={learners},rounds={rounds},"
              f"uploads={row['uploads']},"
              f"uploads_per_s={row['uploads_per_s']:.0f},"
              f"rounds_per_s={row['rounds_per_s']:.2f},"
              f"dropouts={f['dropouts']},rejoins={f['rejoins']},"
              f"lost={f['uploads_lost']},dup={f['uploads_duplicated']},"
              f"orphaned={f['orphaned']}", flush=True)
    return rows


def run_adversarial_arm(learners=1000, rounds=3, fault_seed=7,
                        adversarial_fraction=0.15):
    """Byzantine sweep (``--stress --adversarial-fraction``): rule shoot-out.

    Four sync-protocol arms on a ``value_mode="target"`` SimLearner fleet —
    a faultless FedAvg baseline, then FedAvg / coordinate median / trimmed
    mean under ``adversarial_fraction`` scale + sign-flip adversaries
    (admission screen and quarantine on).  Each row carries the per-fate
    ``adversarial`` counters, the ``admission`` block (rejected / clipped /
    quarantined) and ``final_eval_loss`` against the consensus target, so
    the nightly artifact tracks the headline claim directly: the robust
    rules stay at the baseline's epsilon while FedAvg diverges.
    """
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tests"))
    from stress.harness import run_stress

    from repro.core import FaultSpec

    spec = FaultSpec(seed=fault_seed,
                     adversarial_fraction=adversarial_fraction)
    # Trim deep enough to cover the adversarial minority with headroom,
    # while keeping 2 * trim_k strictly below the fleet size.
    trim_k = max(1, min(int(learners * adversarial_fraction * 1.5),
                        (learners - 1) // 2))
    arms = [
        ("faultless_fedavg", None, "fedavg", 1),
        ("fedavg", spec, "fedavg", 1),
        ("median", spec, "median", 1),
        ("trimmed_mean", spec, "trimmed_mean", trim_k),
    ]
    rows = []
    for arm, arm_spec, rule, tk in arms:
        row = run_stress(protocol="sync", learners=learners, rounds=rounds,
                         spec=arm_spec, aggregation_rule=rule, trim_k=tk,
                         value_mode="target")
        row["bench"] = "adversarial"
        row["arm"] = arm
        row["adversarial_fraction"] = (
            0.0 if arm_spec is None else adversarial_fraction
        )
        rows.append(row)
        adv = row["adversarial"]
        adm = row["admission"]
        print(f"adversarial,{arm},N={learners},rounds={rounds},"
              f"loss={row['final_eval_loss']:.3e},"
              f"scale={adv['scale']},sign_flip={adv['sign_flip']},"
              f"clipped={adm['clipped']},"
              f"quarantined={adm['quarantine_entered']},"
              f"uploads_per_s={row['uploads_per_s']:.0f}", flush=True)
    base = rows[0]["final_eval_loss"]
    fed = rows[1]["final_eval_loss"]
    tm = rows[3]["final_eval_loss"]
    print(f"adversarial headline: baseline={base:.3e}, "
          f"fedavg-under-attack={fed:.3e} "
          f"({fed / max(base, 1e-12):.1e}x worse), "
          f"trimmed_mean-under-attack={tm:.3e} (tracks baseline)",
          flush=True)
    return rows


# ---------------------------------------------------------------------------
# wire-aware semi-sync sizing arm
# ---------------------------------------------------------------------------


def run_schedule(p=1 << 22, n=8, hyperperiod_s=0.5, bandwidth_gbps=1.0,
                 latency_ms=2.0, sps_range=(2e-4, 2e-3)):
    """Wire-aware vs naive semi-sync task sizing under a bandwidth cap.

    Builds a bandwidth-capped controller, seeds ``n`` synthetic learner
    profiles spanning ``sps_range`` seconds-per-step, and sizes each
    learner's task through the real policy + wire model
    (``SemiSyncProtocol.size_task`` fed by ``Controller.wire_time_s`` —
    exactly what the engine's dispatch does).  The modeled round wall-clock
    is the slowest learner's ``steps * sps + round_trip_wire``; wire time is
    virtual by design (the channel never sleeps), so the modeled time *is*
    the round time a bandwidth-capped deployment would see.  The wire-aware
    arm must stay within the hyper-period; the naive arm overshoots by
    roughly the wire time.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Channel, Controller, LearnerProfile, SemiSyncProtocol

    sps = np.geomspace(sps_range[0], sps_range[1], n)
    rows = []
    for arm, wire_aware in (("wire_aware", True), ("naive", False)):
        ctrl = Controller(
            protocol=SemiSyncProtocol(hyperperiod_s=hyperperiod_s,
                                      wire_aware=wire_aware),
            channel=Channel(bandwidth_gbps=bandwidth_gbps,
                            latency_ms=latency_ms),
        )
        ctrl.set_initial_model({"w": jnp.zeros((p,), jnp.float32)})
        round_s = 0.0
        max_steps = 0
        wire_s = 0.0
        for i, s in enumerate(sps):
            lid = f"l{i}"
            prof = LearnerProfile()
            prof.observe_step_time(float(s))
            ctrl._learner_profiles[lid] = prof
            wire_s = ctrl.wire_time_s(lid)
            task = ctrl.protocol.size_task(1, prof, wire_s=wire_s)
            completion_s = task.local_steps * float(s) + wire_s
            round_s = max(round_s, completion_s)
            max_steps = max(max_steps, task.local_steps)
        ctrl.shutdown()
        row = {"bench": "schedule", "arm": arm, "params": p, "learners": n,
               "hyperperiod_s": hyperperiod_s,
               "bandwidth_gbps": bandwidth_gbps,
               "round_trip_wire_s": wire_s,
               "modeled_round_s": round_s,
               "budget_ratio": round_s / hyperperiod_s,
               "within_budget": bool(round_s <= hyperperiod_s),
               "max_steps": max_steps}
        rows.append(row)
        print(f"schedule,{arm},P={p},N={n},bw={bandwidth_gbps}Gbps,"
              f"wire={wire_s*1e3:.1f}ms,round={round_s*1e3:.1f}ms,"
              f"budget={hyperperiod_s*1e3:.0f}ms,"
              f"ratio={row['budget_ratio']:.2f}x,"
              f"within={row['within_budget']}", flush=True)
    aware, naive = rows[0], rows[1]
    print(f"schedule: wire-aware {aware['budget_ratio']:.2f}x of budget "
          f"(within={aware['within_budget']}), naive "
          f"{naive['budget_ratio']:.2f}x (within={naive['within_budget']})",
          flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dispatch", action="store_true",
                    help="train-dispatch scaling vs N (serialize-once claim)")
    ap.add_argument("--schedule", action="store_true",
                    help="bandwidth-capped semi-sync sizing: wire-aware vs naive")
    ap.add_argument("--journal", action="store_true",
                    help="flight-recorder overhead: journaled vs disabled")
    ap.add_argument("--stress", action="store_true",
                    help="1000-learner fault-injecting churn sweep, "
                         "every protocol")
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="stress-arm fault seed (same seed => identical run)")
    ap.add_argument("--adversarial-fraction", type=float, default=0.0,
                    help="with --stress: byzantine rule shoot-out (faultless"
                         " / fedavg / median / trimmed_mean) at this "
                         "adversary rate instead of the churn sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump result rows as JSON")
    args = ap.parse_args(argv)

    if args.dispatch:
        if args.smoke:
            rows = run_dispatch(learner_counts=(4, 8, 16), p=1 << 16, rounds=1)
        else:
            rows = run_dispatch()
    elif args.journal:
        if args.smoke:
            rows = run_journal(p=1 << 16, n=4, rounds=6)
        else:
            rows = run_journal()
    elif args.stress:
        if args.adversarial_fraction > 0:
            if args.smoke:
                rows = run_adversarial_arm(
                    learners=64, rounds=2, fault_seed=args.fault_seed,
                    adversarial_fraction=args.adversarial_fraction)
            else:
                rows = run_adversarial_arm(
                    fault_seed=args.fault_seed,
                    adversarial_fraction=args.adversarial_fraction)
        elif args.smoke:
            rows = run_stress_arm(learners=64, rounds=2,
                                  fault_seed=args.fault_seed)
        else:
            rows = run_stress_arm(fault_seed=args.fault_seed)
    elif args.schedule:
        if args.smoke:
            rows = run_schedule(p=1 << 16, n=4, bandwidth_gbps=0.02)
        else:
            rows = run_schedule()
    else:
        rows = run(learner_counts=(10, 25) if args.smoke else (10, 25, 50))

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == "__main__":
    main()
