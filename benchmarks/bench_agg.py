"""Paper §4.2 aggregation claim: parallelized aggregation ~10x over the
sequential per-tensor controller (Figs. 5c/6c/7c, 'MetisFL gRPC + OpenMP' vs
'MetisFL gRPC').

Arms:
  naive   — per-tensor, per-learner Python-loop FedAvg (the old controller)
  fused   — packed (N,P) single-reduction XLA FedAvg (this repo's controller)
  kernel  — the Pallas fedavg kernel (interpret mode on CPU: correctness-
            representative, not timing-representative; reported separately)
  secure  — masked secure aggregation (overhead of the privacy path)

Model sizes follow the paper: 100k / 1M / 10M params as 100-layer MLPs, so
the naive arm pays the per-tensor Python overhead ~200x per aggregation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import bench
from repro.configs import housing_mlp
from repro.core import aggregation, naive, packing
from repro.core.secure import secure_fedavg
from repro.models import mlp as mlp_model


def _models(size: str, n_learners: int):
    cfg = housing_mlp.config(size)
    base = mlp_model.init_params(jax.random.key(0), cfg)
    models = [
        jax.tree_util.tree_map(lambda x, i=i: x + 0.01 * i, base)
        for i in range(n_learners)
    ]
    return cfg, models


def run(sizes=("100k", "1m", "10m"), learner_counts=(10, 25, 50), iters=3):
    rows = []
    for size in sizes:
        for n in learner_counts:
            cfg, models = _models(size, n)
            weights = [100.0] * n
            stack = jnp.stack([packing.pack_numeric(m) for m in models])
            w = jnp.asarray(weights)
            jax.block_until_ready(stack)

            t_naive = bench(lambda: naive.naive_aggregate(models, weights),
                            warmup=1, iters=iters, block=False)
            t_fused = bench(lambda: aggregation.fedavg(stack, w), iters=iters)
            from repro.kernels import ops as kops
            t_kernel = bench(lambda: kops.fedavg(stack, w), warmup=1, iters=2)
            bufs = [stack[i] for i in range(min(n, 10))]
            t_secure = bench(
                lambda: secure_fedavg(bufs, [1.0] * len(bufs)),
                warmup=1, iters=2,
            )

            speedup = t_naive / t_fused
            rows.append({
                "bench": "aggregation", "size": size, "learners": n,
                "naive_s": t_naive, "fused_s": t_fused,
                "kernel_interpret_s": t_kernel, "secure_s(10)": t_secure,
                "speedup_fused_vs_naive": speedup,
            })
            print(
                f"agg,{size},{n},naive={t_naive*1e3:.2f}ms,"
                f"fused={t_fused*1e3:.3f}ms,kernel(interp)={t_kernel*1e3:.2f}ms,"
                f"secure10={t_secure*1e3:.2f}ms,speedup={speedup:.1f}x",
                flush=True,
            )
    return rows


if __name__ == "__main__":
    run()
