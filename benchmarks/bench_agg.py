"""Paper §4.2 aggregation claim: parallelized aggregation ~10x over the
sequential per-tensor controller (Figs. 5c/6c/7c, 'MetisFL gRPC + OpenMP' vs
'MetisFL gRPC').

Arms (``run``):
  naive   — per-tensor, per-learner Python-loop FedAvg (the old controller)
  fused   — packed (N,P) single-reduction XLA FedAvg (this repo's controller)
  kernel  — the Pallas fedavg kernel (interpret mode on CPU: correctness-
            representative, not timing-representative; reported separately)
  secure  — masked secure aggregation (overhead of the privacy path)

Model sizes follow the paper: 100k / 1M / 10M params as 100-layer MLPs, so
the naive arm pays the per-tensor Python overhead ~200x per aggregation.

Arena-vs-stack comparison (``run_compare``, ``--compare``): the controller's
per-round aggregation latency with the legacy path (rebuild the ``(N, P)``
stack with ``jnp.stack``, then reduce) against the device-resident arena
(rows were written in place at arrival — off the critical path — so the
round's aggregation is just one masked reduction).  Also reports the arena's
per-upload row-write cost, which the stack path pays *again* as part of every
aggregation.  JSON output via ``--json`` for the CI nightly artifact.

Robust-rule arm (``run_robust``, ``--robust``): fedavg vs coordinate median
vs trimmed mean as masked reductions straight off the arena, plus the blocked
Pallas trimmed-mean kernel (interpret mode on CPU) with an allclose parity
check against the jnp rule — tracks the sort-vs-sum "robustness premium" a
byzantine-tolerant controller pays per round.

Fused dequant-into-aggregate (``run_fused``, ``--fused``): the int8-resident
arena's aggregation paths — the fused single-pass reduction
(``aggregation.masked_fedavg_q8``: read int8 rows + f32 group scales once,
never build the f32 ``(N, P)`` stack) against the two-program
dequantize-then-reduce alternative (materialize the f32 stack, then reduce —
the stack crosses memory twice) and against the plain f32 arena, plus the
blocked Pallas fused kernel (interpret mode on CPU) with an allclose parity
check.  Bytes moved: ``~N·P·(1 + 4/group) + 4P`` fused vs ``~9·N·P``
dequant-then-reduce; see ``benchmarks/roofline_table.py`` and docs/ARENA.md.

Sparse top-k aggregation (``run_sparse``, ``--sparse``): the topk-resident
arena's masked scatter-accumulate (``aggregation.masked_fedavg_topk``: read
the ``(N, k)`` index/value streams once, never build the dense ``(N, P)``
stack) against densify-then-reduce (materialize the f32 stack, then reduce)
and against the int8 arena's fused dequant-into-aggregate over the same
rows — the two wire-compression paths' per-round costs side by side, with
per-shape parity checks.  Bytes moved: ``~8·N·k + 4·P`` scatter vs
``~8·N·P`` densify-then-reduce; see ``benchmarks/roofline_table.py``.

Sharded-vs-single-device arena (``run_sharded``, ``--sharded``): the same
masked reduction and row write on a mesh-sharded arena
(``ArenaStore(mesh=...)``, every visible device) against the single-device
arena.  Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on
CPU (as the CI nightly does) for an 8-shard layout; on real hardware the
mesh spans the accelerators.  Includes an allclose parity check per shape so
the bench doubles as a smoke test.  See ``benchmarks/README.md``.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.timing import bench
from repro.configs import housing_mlp
from repro.core import aggregation, naive, packing
from repro.core.secure import secure_fedavg
from repro.core.store import ArenaStore
from repro.models import mlp as mlp_model


def _models(size: str, n_learners: int):
    cfg = housing_mlp.config(size)
    base = mlp_model.init_params(jax.random.key(0), cfg)
    models = [
        jax.tree_util.tree_map(lambda x, i=i: x + 0.01 * i, base)
        for i in range(n_learners)
    ]
    return cfg, models


def run(sizes=("100k", "1m", "10m"), learner_counts=(10, 25, 50), iters=3):
    rows = []
    for size in sizes:
        for n in learner_counts:
            cfg, models = _models(size, n)
            weights = [100.0] * n
            stack = jnp.stack([packing.pack_numeric(m) for m in models])
            w = jnp.asarray(weights)
            jax.block_until_ready(stack)

            t_naive = bench(lambda: naive.naive_aggregate(models, weights),
                            warmup=1, iters=iters, block=False)
            t_fused = bench(lambda: aggregation.fedavg(stack, w), iters=iters)
            from repro.kernels import ops as kops
            t_kernel = bench(lambda: kops.fedavg(stack, w), warmup=1, iters=2)
            bufs = [stack[i] for i in range(min(n, 10))]
            t_secure = bench(
                lambda: secure_fedavg(bufs, [1.0] * len(bufs)),
                warmup=1, iters=2,
            )

            speedup = t_naive / t_fused
            rows.append({
                "bench": "aggregation", "size": size, "learners": n,
                "naive_s": t_naive, "fused_s": t_fused,
                "kernel_interpret_s": t_kernel, "secure_s(10)": t_secure,
                "speedup_fused_vs_naive": speedup,
            })
            print(
                f"agg,{size},{n},naive={t_naive*1e3:.2f}ms,"
                f"fused={t_fused*1e3:.3f}ms,kernel(interp)={t_kernel*1e3:.2f}ms,"
                f"secure10={t_secure*1e3:.2f}ms,speedup={speedup:.1f}x",
                flush=True,
            )
    return rows


def run_compare(learner_counts=(8, 32, 64), param_counts=(1 << 20, 1 << 22),
                iters=10):
    """Arena-vs-stack per-round aggregation latency.

    Both arms aggregate the same N fresh learner uploads:

    * **stack** — what ``Controller._aggregate(store_mode="stack")`` runs per
      round: ``jnp.stack`` over the N stored buffers (the O(N·P) rebuild)
      followed by the fused reduction.
    * **arena** — what ``store_mode="arena"`` runs per round: one masked
      reduction straight over the persistent device buffer.  Uploads were
      written in place at arrival (overlapped with the training round);
      ``arena_write_s`` reports that per-upload cost for honesty — the stack
      path pays the equivalent copy *inside* the timed aggregation instead.
    """
    rows = []
    for p in param_counts:
        for n in learner_counts:
            buffers = [
                jax.random.normal(jax.random.key(i), (p,), jnp.float32)
                for i in range(n)
            ]
            jax.block_until_ready(buffers)
            weights = [float(10 * (i + 1)) for i in range(n)]
            w = jnp.asarray(weights, jnp.float32)

            def stack_round():
                stack = jnp.stack(buffers, axis=0)
                return aggregation.fedavg(stack, w)

            t_stack = bench(stack_round, warmup=2, iters=iters)

            arena = ArenaStore(num_params=p, n_max=n, row_align=1024)
            for i, buf in enumerate(buffers):
                arena.write(f"l{i}", buf, weight=weights[i])

            def arena_round():
                with arena.lock:
                    return aggregation.masked_weighted_average(
                        arena.buffer, arena.weights, arena.mask
                    )[: arena.num_params]

            t_arena = bench(arena_round, warmup=2, iters=iters)

            # per-upload in-place row write (amortized at arrival, off the
            # aggregation critical path) — blocked on the device copy so the
            # reported cost is the real O(P) write, not dispatch overhead
            def arena_write():
                arena.write("l0", buffers[0], weight=weights[0])
                jax.block_until_ready(arena.buffer)

            t_write = bench(arena_write, warmup=2, iters=iters, block=False)

            speedup = t_stack / t_arena
            row = {
                "bench": "arena_vs_stack", "params": p, "learners": n,
                "stack_round_s": t_stack, "arena_round_s": t_arena,
                "arena_write_s": t_write,
                "speedup_arena_vs_stack": speedup,
            }
            rows.append(row)
            print(
                f"compare,P={p},N={n},stack={t_stack*1e3:.2f}ms,"
                f"arena={t_arena*1e3:.2f}ms,write={t_write*1e3:.3f}ms,"
                f"speedup={speedup:.2f}x",
                flush=True,
            )
            del arena, buffers
    return rows


def run_robust(learner_counts=(8, 32, 64), param_counts=(1 << 20, 1 << 22),
               iters=10, trim_k=2):
    """Robust-rule aggregation latency off the arena (``--robust``).

    The same masked-reduction shape as ``run_compare``'s arena arm, across
    the three aggregation rules a controller can run: fedavg (the weighted
    mean baseline), coordinate median, and trimmed mean — all straight off
    the device-resident arena, no re-stack — plus the blocked Pallas
    trimmed-mean kernel (interpret mode on CPU: correctness-representative,
    not timing-representative; reported separately).  A per-shape allclose
    parity check between the jnp rule and the kernel keeps the bench
    honest.  The robust premium (sort vs sum) is the price of byzantine
    tolerance; docs/STRESS.md shows what it buys.
    """
    import numpy as np

    from repro.kernels import ops as kops

    rows = []
    for p in param_counts:
        for n in learner_counts:
            arena = ArenaStore(num_params=p, n_max=n, row_align=1024)
            for i in range(n):
                arena.write(
                    f"l{i}",
                    jax.random.normal(jax.random.key(i), (p,), jnp.float32),
                    weight=float(10 * (i + 1)),
                )

            def fedavg_round():
                with arena.lock:
                    return aggregation.masked_weighted_average(
                        arena.buffer, arena.weights, arena.mask
                    )[: arena.num_params]

            def median_round():
                with arena.lock:
                    return aggregation.masked_coordinate_median(
                        arena.buffer, arena.weights, arena.mask
                    )[: arena.num_params]

            def trimmed_round():
                with arena.lock:
                    return aggregation.masked_trimmed_mean(
                        arena.buffer, arena.weights, arena.mask, trim_k
                    )[: arena.num_params]

            def kernel_round():
                with arena.lock:
                    return kops.masked_trimmed_mean(
                        arena.buffer, arena.weights, arena.mask, trim_k=trim_k
                    )[: arena.num_params]

            np.testing.assert_allclose(
                np.asarray(trimmed_round()), np.asarray(kernel_round()),
                rtol=1e-5, atol=1e-6,
            )
            t_fedavg = bench(fedavg_round, warmup=2, iters=iters)
            t_median = bench(median_round, warmup=2, iters=iters)
            t_trimmed = bench(trimmed_round, warmup=2, iters=iters)
            t_kernel = bench(kernel_round, warmup=1, iters=2)

            row = {
                "bench": "robust_rules", "params": p, "learners": n,
                "trim_k": trim_k,
                "fedavg_s": t_fedavg, "median_s": t_median,
                "trimmed_mean_s": t_trimmed,
                "kernel_interpret_s": t_kernel,
                "robust_premium_median": t_median / t_fedavg,
                "robust_premium_trimmed": t_trimmed / t_fedavg,
            }
            rows.append(row)
            print(
                f"robust,P={p},N={n},fedavg={t_fedavg*1e3:.2f}ms,"
                f"median={t_median*1e3:.2f}ms,"
                f"trimmed={t_trimmed*1e3:.2f}ms,"
                f"kernel(interp)={t_kernel*1e3:.2f}ms,"
                f"premium={t_trimmed/t_fedavg:.2f}x",
                flush=True,
            )
            del arena
    return rows


def run_fused(shapes=((1 << 22, 8), (1 << 22, 32), (1 << 22, 64),
                      (1 << 24, 32)),
              iters=10):
    """Fused dequant-into-aggregate vs dequantize-then-reduce (``--fused``).

    Every arm aggregates the same N uploads resident in an int8
    :class:`ArenaStore` (plus an f32 twin for the baseline):

    * **fused** — ``aggregation.masked_fedavg_q8``: one program reads the
      int8 rows and their per-group f32 scales and emits the masked weighted
      mean; the f32 ``(N, P)`` stack is never materialized.
    * **dequant_reduce** — what an int8-resident arena costs *without* the
      fused path: program 1 dequantizes into an f32 ``(N, P)`` stack, program
      2 reduces it.  The stack is written and re-read — ``~9·N·P`` bytes vs
      the fused pass's ``~N·P·(1 + 4/group) + 4P``.
    * **f32_arena** — the plain f32 arena reduction, for the residency-vs-
      latency trade-off (4 bytes/param resident vs ~1.016).
    * **kernel** — the blocked Pallas fused kernel
      (``kernels/ops.masked_fedavg_q8``; interpret mode on CPU:
      correctness-representative, not timing-representative).

    Per-shape allclose parity (fused vs dequant-then-reduce vs the Pallas
    kernel) keeps the bench honest; ``shapes`` is ``(P, N)`` pairs rather
    than a cross product so the big-P row doesn't multiply against big N
    (the dequant arm's f32 stack is the memory hog).
    """
    import functools

    import numpy as np

    from repro.kernels import ops as kops

    @functools.partial(jax.jit, static_argnames=("group",))
    def dequant_rows(q, scales, group):
        n, p = q.shape
        rows = q.astype(jnp.float32).reshape(n, p // group, group)
        return (rows * scales[:, :, None]).reshape(n, p)

    out_rows = []
    for p, n in shapes:
        arena = ArenaStore(num_params=p, n_max=n, row_align=1024,
                           arena_dtype="int8")
        f32 = ArenaStore(num_params=p, n_max=n, row_align=1024)
        for i in range(n):
            buf = jax.random.normal(jax.random.key(i), (p,), jnp.float32)
            arena.write(f"l{i}", buf, weight=float(10 * (i + 1)))
            f32.write(f"l{i}", buf, weight=float(10 * (i + 1)))
            del buf
        group = arena.qgroup

        def fused_round():
            with arena.lock:
                return aggregation.masked_fedavg_q8(
                    arena.buffer, arena.scales, arena.weights, arena.mask,
                    group,
                )[: arena.num_params]

        def dequant_reduce_round():
            with arena.lock:
                stack = dequant_rows(arena.buffer, arena.scales, group)
                jax.block_until_ready(stack)  # two programs, like real code
                return aggregation.masked_weighted_average(
                    stack, arena.weights, arena.mask
                )[: arena.num_params]

        def f32_round():
            with f32.lock:
                return aggregation.masked_weighted_average(
                    f32.buffer, f32.weights, f32.mask
                )[: f32.num_params]

        def kernel_round():
            with arena.lock:
                return kops.masked_fedavg_q8(
                    arena.buffer, arena.scales, arena.weights, arena.mask,
                    group,
                )[: arena.num_params]

        want = np.asarray(dequant_reduce_round())
        np.testing.assert_allclose(np.asarray(fused_round()), want,
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(kernel_round()), want,
                                   rtol=2e-5, atol=2e-5)
        t_fused = bench(fused_round, warmup=2, iters=iters)
        t_dq = bench(dequant_reduce_round, warmup=2, iters=iters)
        t_f32 = bench(f32_round, warmup=2, iters=iters)
        t_kernel = bench(kernel_round, warmup=1, iters=2)

        speedup = t_dq / t_fused
        resident_q8 = arena.buffer.nbytes + arena.scales.nbytes
        row = {
            "bench": "fused_q8", "params": p, "learners": n, "group": group,
            "fused_s": t_fused, "dequant_reduce_s": t_dq,
            "f32_arena_s": t_f32, "kernel_interpret_s": t_kernel,
            "resident_bytes_int8": resident_q8,
            "resident_bytes_f32": f32.buffer.nbytes,
            "shrink_resident": f32.buffer.nbytes / resident_q8,
            "speedup_fused_vs_dequant": speedup,
        }
        out_rows.append(row)
        print(
            f"fused,P={p},N={n},fused={t_fused*1e3:.2f}ms,"
            f"dequant_reduce={t_dq*1e3:.2f}ms,f32={t_f32*1e3:.2f}ms,"
            f"kernel(interp)={t_kernel*1e3:.2f}ms,"
            f"shrink={row['shrink_resident']:.2f}x,speedup={speedup:.2f}x",
            flush=True,
        )
        del arena, f32
    return out_rows


def run_sparse(shapes=((1 << 22, 8), (1 << 22, 32), (1 << 22, 64),
                       (1 << 24, 32)),
               k_divisor=64, iters=10):
    """Sparse top-k aggregation: scatter-accumulate vs alternatives
    (``--sparse``).

    Every arm aggregates the *same* N sparse top-k uploads (k = P /
    ``k_divisor`` coordinates per row, the ``sparse_mode="direct"``
    resident layout):

    * **scatter** — ``aggregation.masked_fedavg_topk``: one program scatters
      the ``(N, k)`` weighted value streams into the f32 output row; the
      dense ``(N, P)`` stack is never materialized (``~8·N·k + 4·P`` bytes).
    * **densify_reduce** — what ``sparse_mode="densify"`` costs at
      aggregation time if the densified rows were *not* arena-resident:
      program 1 scatters each row into a dense f32 ``(N, P)`` stack, program
      2 runs the masked reduction.  The stack is written and re-read —
      ``~8·N·P`` bytes.
    * **fused_q8** — the int8-resident arena's fused dequant-into-aggregate
      (``aggregation.masked_fedavg_q8``) over the same densified rows,
      quantized: the other wire-compression path's per-round cost, for the
      codec trade-off table in docs/ARENA.md.

    Per-shape parity: scatter must match densify-then-reduce to f32
    tolerance (both are exact reorderings of the same sum), and fused_q8
    must land inside the per-group quantization bound of that target.
    ``shapes`` is ``(P, N)`` pairs, same convention as :func:`run_fused`.
    """
    import functools

    import numpy as np

    @functools.partial(jax.jit, static_argnames=("width",))
    def densify_rows(idx, val, width):
        n = idx.shape[0]
        dense = jnp.zeros((n, width), jnp.float32)
        return dense.at[jnp.arange(n)[:, None], idx].add(val)

    out_rows = []
    for p, n in shapes:
        k = max(1, p // k_divisor)
        arena = ArenaStore(num_params=p, n_max=n, row_align=1024,
                           arena_dtype="topk", sparse_k=k)
        q8 = ArenaStore(num_params=p, n_max=n, row_align=1024,
                        arena_dtype="int8")
        amax = 0.0
        for i in range(n):
            kidx, kkey = jax.random.split(jax.random.key(i))
            idx = jax.random.choice(kidx, p, shape=(k,), replace=False)
            val = jax.random.normal(kkey, (k,), jnp.float32)
            arena.write_sparse(f"l{i}", idx.astype(jnp.int32), val,
                               weight=float(10 * (i + 1)))
            q8.write(f"l{i}",
                     densify_rows(idx[None, :].astype(jnp.int32),
                                  val[None, :], arena.padded_params)[0],
                     weight=float(10 * (i + 1)))
            amax = max(amax, float(jnp.max(jnp.abs(val))))
        group = q8.qgroup
        width = arena.padded_params

        def scatter_round():
            with arena.lock:
                return aggregation.masked_fedavg_topk(
                    arena.indices, arena.buffer, arena.weights, arena.mask,
                    width,
                )[: arena.num_params]

        def densify_reduce_round():
            with arena.lock:
                stack = densify_rows(arena.indices, arena.buffer, width)
                jax.block_until_ready(stack)  # two programs, like real code
                return aggregation.masked_weighted_average(
                    stack, arena.weights, arena.mask
                )[: arena.num_params]

        def fused_q8_round():
            with q8.lock:
                return aggregation.masked_fedavg_q8(
                    q8.buffer, q8.scales, q8.weights, q8.mask, group,
                )[: q8.num_params]

        want = np.asarray(densify_reduce_round())
        np.testing.assert_allclose(np.asarray(scatter_round()), want,
                                   rtol=2e-5, atol=2e-5)
        # fused_q8 aggregates the quantized twin of the same rows: the
        # weighted mean can drift at most one group scale (amax/127) off.
        np.testing.assert_allclose(np.asarray(fused_q8_round()), want,
                                   atol=amax / 127 + 1e-6)
        t_scatter = bench(scatter_round, warmup=2, iters=iters)
        t_dense = bench(densify_reduce_round, warmup=2, iters=iters)
        t_q8 = bench(fused_q8_round, warmup=2, iters=iters)

        speedup = t_dense / t_scatter
        resident = arena.buffer.nbytes + arena.indices.nbytes
        row = {
            "bench": "sparse_topk", "params": p, "learners": n, "k": k,
            "scatter_s": t_scatter, "densify_reduce_s": t_dense,
            "fused_q8_s": t_q8,
            "resident_bytes_topk": resident,
            "resident_bytes_f32": 4 * n * width,
            "shrink_resident": 4 * n * width / resident,
            "speedup_scatter_vs_densify": speedup,
        }
        out_rows.append(row)
        print(
            f"sparse,P={p},N={n},k={k},scatter={t_scatter*1e3:.2f}ms,"
            f"densify_reduce={t_dense*1e3:.2f}ms,fused_q8={t_q8*1e3:.2f}ms,"
            f"shrink={row['shrink_resident']:.1f}x,speedup={speedup:.2f}x",
            flush=True,
        )
        del arena, q8
    return out_rows


def run_sharded(learner_counts=(8, 32), param_counts=(1 << 20, 1 << 22),
                iters=10):
    """Sharded-vs-single-device arena: masked reduction + row-write latency.

    Both arms hold the same N uploads in an :class:`ArenaStore`; the sharded
    arm lays the buffer out column-sharded over a 1-D ``("data",)`` mesh of
    every visible device (``launch/mesh.make_controller_mesh``) and reduces
    per shard with zero collectives.  On CPU with forced host devices the
    sharded arm mostly demonstrates *layout correctness* (host "devices"
    share one socket); on real accelerators each shard reduces on its own
    chip's HBM.  A per-shape allclose parity assert keeps the bench honest.
    """
    import numpy as np

    from repro.launch.mesh import make_controller_mesh

    n_dev = jax.device_count()
    if n_dev == 1:
        print("sharded: only 1 device visible — layout is a no-op; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU",
              flush=True)
    mesh = make_controller_mesh()

    rows = []
    for p in param_counts:
        for n in learner_counts:
            buffers = [
                jax.random.normal(jax.random.key(i), (p,), jnp.float32)
                for i in range(n)
            ]
            jax.block_until_ready(buffers)
            weights = [float(10 * (i + 1)) for i in range(n)]

            single = ArenaStore(num_params=p, n_max=n, row_align=1024)
            sharded = ArenaStore(num_params=p, n_max=n, row_align=1024, mesh=mesh)
            for i, buf in enumerate(buffers):
                single.write(f"l{i}", buf, weight=weights[i])
                sharded.write(f"l{i}", buf, weight=weights[i])

            def single_round():
                with single.lock:
                    return aggregation.masked_weighted_average(
                        single.buffer, single.weights, single.mask
                    )[: single.num_params]

            sharded_fn = aggregation.masked_fedavg_sharded(mesh)

            def sharded_round():
                with sharded.lock:
                    return sharded_fn(
                        sharded.buffer, sharded.weights, sharded.mask
                    )[: sharded.num_params]

            np.testing.assert_allclose(
                np.asarray(single_round()), np.asarray(sharded_round()),
                rtol=1e-5, atol=1e-6,
            )
            t_single = bench(single_round, warmup=2, iters=iters)
            t_sharded = bench(sharded_round, warmup=2, iters=iters)

            def sharded_write():
                sharded.write("l0", buffers[0], weight=weights[0])
                jax.block_until_ready(sharded.buffer)

            t_write = bench(sharded_write, warmup=2, iters=iters, block=False)

            row = {
                "bench": "arena_sharded", "params": p, "learners": n,
                "n_shards": sharded.n_shards,
                "shard_width": sharded.shard_width,
                "single_round_s": t_single, "sharded_round_s": t_sharded,
                "sharded_write_s": t_write,
                "speedup_sharded_vs_single": t_single / t_sharded,
            }
            rows.append(row)
            print(
                f"sharded,P={p},N={n},shards={sharded.n_shards},"
                f"single={t_single*1e3:.2f}ms,sharded={t_sharded*1e3:.2f}ms,"
                f"write={t_write*1e3:.3f}ms,"
                f"speedup={t_single/t_sharded:.2f}x",
                flush=True,
            )
            del single, sharded, buffers
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", action="store_true",
                    help="arena-vs-stack per-round aggregation latency")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-sharded vs single-device arena aggregation")
    ap.add_argument("--robust", action="store_true",
                    help="robust rules (median / trimmed mean) vs fedavg "
                         "off the arena, incl. the Pallas kernel")
    ap.add_argument("--fused", action="store_true",
                    help="int8 arena: fused dequant-into-aggregate vs "
                         "dequantize-then-reduce vs the f32 arena")
    ap.add_argument("--sparse", action="store_true",
                    help="top-k arena: masked scatter-accumulate vs "
                         "densify-then-reduce vs the fused int8 path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump result rows as JSON")
    args = ap.parse_args(argv)

    if args.sparse:
        if args.smoke:
            rows = run_sparse(shapes=((1 << 16, 4), (1 << 16, 32)),
                              k_divisor=64, iters=3)
        else:
            rows = run_sparse()
    elif args.fused:
        if args.smoke:
            rows = run_fused(shapes=((1 << 16, 4), (1 << 16, 8)), iters=3)
        else:
            rows = run_fused()
    elif args.sharded:
        if args.smoke:
            rows = run_sharded(learner_counts=(4, 8), param_counts=(1 << 16,),
                               iters=3)
        else:
            rows = run_sharded()
    elif args.robust:
        if args.smoke:
            rows = run_robust(learner_counts=(4, 8), param_counts=(1 << 16,),
                              iters=3, trim_k=1)
        else:
            rows = run_robust()
    elif args.compare:
        if args.smoke:
            rows = run_compare(learner_counts=(4, 8), param_counts=(1 << 16,),
                               iters=3)
        else:
            rows = run_compare()
    else:
        if args.smoke:
            rows = run(sizes=("100k",), learner_counts=(4,), iters=2)
        else:
            rows = run()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == "__main__":
    main()
