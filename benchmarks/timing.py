"""Timing helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable

import jax

__all__ = ["bench", "BenchResult"]


def bench(fn: Callable, *args, warmup: int = 2, iters: int = 5,
          block: bool = True) -> float:
    """Median wall-clock seconds per call."""
    for _ in range(warmup):
        out = fn(*args)
        if block:
            jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if block:
            jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
