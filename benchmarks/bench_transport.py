"""Transport / serialization benchmark (the dispatch-time share of
Figs. 5a/5d...): per-tensor pickle (naive) vs flat-byte packing (paper's
proto-tensor) vs flat packing + int8 Pallas codec (beyond paper).

Reports bytes-on-wire and serialize+deserialize wall time per model size.
"""

from __future__ import annotations

import pickle

import jax
import numpy as np

from benchmarks.timing import bench
from repro.configs import housing_mlp
from repro.core import naive, packing
from repro.kernels.ops import QuantCodec
from repro.models import mlp as mlp_model


def run(sizes=("100k", "1m", "10m")):
    rows = []
    for size in sizes:
        cfg = housing_mlp.config(size)
        params = mlp_model.init_params(jax.random.key(0), cfg)
        treedef = jax.tree_util.tree_structure(params)

        def naive_rt():
            blobs = naive.naive_serialize(params)
            naive.naive_deserialize(blobs, treedef)
            return sum(len(b) for b in blobs)

        def packed_rt():
            buf, m = packing.pack_bytes(params)
            packing.unpack_bytes(buf, m)
            return buf.nbytes

        codec = QuantCodec()

        def quant_rt():
            enc = codec.encode(params)
            buf, m = packing.pack_bytes(enc)
            codec.decode(packing.unpack_bytes(buf, m))
            return buf.nbytes

        t_naive = bench(naive_rt, warmup=1, iters=3, block=False)
        t_packed = bench(packed_rt, warmup=1, iters=3, block=False)
        t_quant = bench(quant_rt, warmup=1, iters=2, block=False)
        b_naive, b_packed, b_quant = naive_rt(), packed_rt(), quant_rt()
        rows.append({
            "bench": "transport", "size": size,
            "naive_s": t_naive, "packed_s": t_packed, "quant_s": t_quant,
            "naive_bytes": b_naive, "packed_bytes": b_packed,
            "quant_bytes": b_quant,
        })
        print(
            f"transport,{size},naive={t_naive*1e3:.2f}ms/{b_naive/1e6:.1f}MB,"
            f"packed={t_packed*1e3:.2f}ms/{b_packed/1e6:.1f}MB,"
            f"int8={t_quant*1e3:.2f}ms/{b_quant/1e6:.1f}MB,"
            f"wire_saving={b_naive/b_quant:.1f}x",
            flush=True,
        )
    return rows


if __name__ == "__main__":
    run()
