"""Transport / serialization benchmark (the dispatch-time share of
Figs. 5a/5d...): per-tensor pickle (naive) vs flat-byte packing (paper's
proto-tensor) vs flat packing + int8 Pallas codec (beyond paper), plus the
serialize-once broadcast fan-out vs legacy per-send dispatch.

Reports bytes-on-wire and serialize+deserialize wall time per model size.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.timing import bench
from repro.configs import housing_mlp
from repro.core import Channel, naive, packing
from repro.kernels.ops import QuantCodec
from repro.models import mlp as mlp_model


def run(sizes=("100k", "1m", "10m")):
    rows = []
    for size in sizes:
        cfg = housing_mlp.config(size)
        params = mlp_model.init_params(jax.random.key(0), cfg)
        treedef = jax.tree_util.tree_structure(params)

        def naive_rt():
            blobs = naive.naive_serialize(params)
            naive.naive_deserialize(blobs, treedef)
            return sum(len(b) for b in blobs)

        def packed_rt():
            buf, m = packing.pack_bytes(params)
            packing.unpack_bytes(buf, m)
            return buf.nbytes

        codec = QuantCodec()

        def quant_rt():
            enc = codec.encode(params)
            buf, m = packing.pack_bytes(enc)
            codec.decode(packing.unpack_bytes(buf, m))
            return buf.nbytes

        t_naive = bench(naive_rt, warmup=1, iters=3, block=False)
        t_packed = bench(packed_rt, warmup=1, iters=3, block=False)
        t_quant = bench(quant_rt, warmup=1, iters=2, block=False)
        b_naive, b_packed, b_quant = naive_rt(), packed_rt(), quant_rt()
        rows.append({
            "bench": "transport", "size": size,
            "naive_s": t_naive, "packed_s": t_packed, "quant_s": t_quant,
            "naive_bytes": b_naive, "packed_bytes": b_packed,
            "quant_bytes": b_quant,
        })
        print(
            f"transport,{size},naive={t_naive*1e3:.2f}ms/{b_naive/1e6:.1f}MB,"
            f"packed={t_packed*1e3:.2f}ms/{b_packed/1e6:.1f}MB,"
            f"int8={t_quant*1e3:.2f}ms/{b_quant/1e6:.1f}MB,"
            f"wire_saving={b_naive/b_quant:.1f}x",
            flush=True,
        )
    return rows


def run_broadcast(sizes=("1m", "10m"), n_recipients=32, iters=3):
    """Serialize-once fan-out vs legacy per-send dispatch, per model size.

    ``persend`` re-serializes the pytree for every recipient (the old
    ``Channel.send`` loop, O(N·P)); ``broadcast`` serializes once straight
    off the flat numeric buffer and stamps N shared envelopes (O(P + N)).
    A bit-identity check against the per-send bytes keeps the arms honest.
    """
    rows = []
    for size in sizes:
        cfg = housing_mlp.config(size)
        params = mlp_model.init_params(jax.random.key(0), cfg)
        manifest = packing.build_manifest(params)
        numeric = packing.pack_numeric(params)
        jax.block_until_ready(numeric)

        def persend():
            ch = Channel()
            for _ in range(n_recipients):
                env = ch.send(params)
            return env

        def broadcast():
            ch = Channel()
            bc = ch.broadcast(params=params, buffer=numeric, manifest=manifest)
            for _ in range(n_recipients):
                env = bc.to()
            return env

        # honesty: both arms put identical bytes on the wire
        np.testing.assert_array_equal(
            np.asarray(persend().buffer), np.asarray(broadcast().buffer)
        )
        t_persend = bench(persend, warmup=1, iters=iters, block=False)
        t_broadcast = bench(broadcast, warmup=1, iters=iters, block=False)
        rows.append({
            "bench": "broadcast", "size": size, "recipients": n_recipients,
            "persend_s": t_persend, "broadcast_s": t_broadcast,
            "speedup_broadcast_vs_persend": t_persend / t_broadcast,
        })
        print(
            f"broadcast,{size},N={n_recipients},"
            f"persend={t_persend*1e3:.2f}ms,broadcast={t_broadcast*1e3:.2f}ms,"
            f"speedup={t_persend/t_broadcast:.1f}x",
            flush=True,
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump result rows as JSON")
    args = ap.parse_args(argv)

    if args.smoke:
        rows = run(sizes=("100k",)) + run_broadcast(sizes=("100k",),
                                                    n_recipients=8, iters=2)
    else:
        rows = run() + run_broadcast()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == "__main__":
    main()
