"""Transport / serialization benchmark (the dispatch-time share of
Figs. 5a/5d...): per-tensor pickle (naive) vs flat-byte packing (paper's
proto-tensor) vs flat packing + int8 Pallas codec (beyond paper), plus the
serialize-once broadcast fan-out vs legacy per-send dispatch, plus the
measured **uplink** (``--upload``): raw vs int8 vs top-k sparse codecs over the
``Channel.upload``/``recv_upload`` half — the dominant wire direction of a
federation round (N uploads vs 1 broadcast).

Reports bytes-on-wire and serialize+deserialize wall time per model size.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import bench
from repro.configs import housing_mlp
from repro.core import Channel, naive, packing
from repro.core.transport import TopkUploadCodec
from repro.kernels.ops import QuantCodec
from repro.models import mlp as mlp_model


def run(sizes=("100k", "1m", "10m")):
    rows = []
    for size in sizes:
        cfg = housing_mlp.config(size)
        params = mlp_model.init_params(jax.random.key(0), cfg)
        treedef = jax.tree_util.tree_structure(params)

        def naive_rt():
            blobs = naive.naive_serialize(params)
            naive.naive_deserialize(blobs, treedef)
            return sum(len(b) for b in blobs)

        def packed_rt():
            buf, m = packing.pack_bytes(params)
            packing.unpack_bytes(buf, m)
            return buf.nbytes

        codec = QuantCodec()

        def quant_rt():
            enc = codec.encode(params)
            buf, m = packing.pack_bytes(enc)
            codec.decode(packing.unpack_bytes(buf, m))
            return buf.nbytes

        t_naive = bench(naive_rt, warmup=1, iters=3, block=False)
        t_packed = bench(packed_rt, warmup=1, iters=3, block=False)
        t_quant = bench(quant_rt, warmup=1, iters=2, block=False)
        b_naive, b_packed, b_quant = naive_rt(), packed_rt(), quant_rt()
        rows.append({
            "bench": "transport", "size": size,
            "naive_s": t_naive, "packed_s": t_packed, "quant_s": t_quant,
            "naive_bytes": b_naive, "packed_bytes": b_packed,
            "quant_bytes": b_quant,
        })
        print(
            f"transport,{size},naive={t_naive*1e3:.2f}ms/{b_naive/1e6:.1f}MB,"
            f"packed={t_packed*1e3:.2f}ms/{b_packed/1e6:.1f}MB,"
            f"int8={t_quant*1e3:.2f}ms/{b_quant/1e6:.1f}MB,"
            f"wire_saving={b_naive/b_quant:.1f}x",
            flush=True,
        )
    return rows


def run_broadcast(sizes=("1m", "10m"), n_recipients=32, iters=3):
    """Serialize-once fan-out vs legacy per-send dispatch, per model size.

    ``persend`` re-serializes the pytree for every recipient (the old
    ``Channel.send`` loop, O(N·P)); ``broadcast`` serializes once straight
    off the flat numeric buffer and stamps N shared envelopes (O(P + N)).
    A bit-identity check against the per-send bytes keeps the arms honest.
    """
    rows = []
    for size in sizes:
        cfg = housing_mlp.config(size)
        params = mlp_model.init_params(jax.random.key(0), cfg)
        manifest = packing.build_manifest(params)
        numeric = packing.pack_numeric(params)
        jax.block_until_ready(numeric)

        def persend():
            ch = Channel()
            for _ in range(n_recipients):
                env = ch.send(params)
            return env

        def broadcast():
            ch = Channel()
            bc = ch.broadcast(params=params, buffer=numeric, manifest=manifest)
            for _ in range(n_recipients):
                env = bc.to()
            return env

        # honesty: both arms put identical bytes on the wire
        np.testing.assert_array_equal(
            np.asarray(persend().buffer), np.asarray(broadcast().buffer)
        )
        t_persend = bench(persend, warmup=1, iters=iters, block=False)
        t_broadcast = bench(broadcast, warmup=1, iters=iters, block=False)
        rows.append({
            "bench": "broadcast", "size": size, "recipients": n_recipients,
            "persend_s": t_persend, "broadcast_s": t_broadcast,
            "speedup_broadcast_vs_persend": t_persend / t_broadcast,
        })
        print(
            f"broadcast,{size},N={n_recipients},"
            f"persend={t_persend*1e3:.2f}ms,broadcast={t_broadcast*1e3:.2f}ms,"
            f"speedup={t_persend/t_broadcast:.1f}x",
            flush=True,
        )
    return rows


def run_upload(sizes=(2**23,), iters=2):
    """Measured uplink: raw vs int8 vs top-k sparse upload codecs.

    Each arm times **one** learner row through the channel's upload half
    (``Channel.upload`` → ``recv_upload``) and reports that upload's wire
    bytes — per-roundtrip units, same convention as :func:`run`, so MB/s is
    computable straight off the JSON row.  Honesty checks: the raw arm must
    round-trip bit-exactly; the int8 arm must stay inside the per-group
    quantization bound; the topk arms must be zero off the selected
    coordinates and exact (f32 values) or inside the quantization bound
    (int8-grouped values) on them.

    The sparse arms sweep ``k = P/16, P/64, P/256`` with f32 values plus
    ``k = P/64`` with int8-grouped values, and each row carries its byte
    ratio against the raw and int8 arms.  The contract the nightly JSON
    tracks (and this function asserts — bytes are deterministic): at
    ``k = P/64`` the topk payload is **>= 8x** smaller than raw and
    **>= 2x** smaller than the int8 codec.
    """
    rows = []
    for p in sizes:
        p = int(p)
        buf = jnp.asarray(
            np.random.default_rng(0).normal(size=(p,)).astype(np.float32)
        )
        jax.block_until_ready(buf)
        np_buf = np.asarray(buf)
        amax = float(np.max(np.abs(np_buf)))

        specs = [("raw", "raw"), ("int8", "int8")]
        for frac in (16, 64, 256):
            specs.append(
                (f"topk_p{frac}", TopkUploadCodec(k=max(1, p // frac)))
            )
        specs.append(
            ("topk_p64_q8",
             TopkUploadCodec(k=max(1, p // 64), value_dtype="int8"))
        )

        arms = {}
        for name, codec in specs:
            ch = Channel(upload_codec=codec)

            def roundtrip(ch=ch):
                env = ch.upload(buf)
                row = ch.recv_upload(env)
                jax.block_until_ready(row)
                return env

            env = roundtrip()
            got = np.asarray(ch.recv_upload(env))
            if name == "raw":
                np.testing.assert_array_equal(got, np_buf)
            elif name == "int8":
                assert float(np.max(np.abs(got - np_buf))) <= amax / 127
            else:
                idx, _ = ch.upload_codec.unpack_coords(env.payload, p)
                idx = np.asarray(idx)
                off = np.ones(p, bool)
                off[idx] = False
                assert not got[off].any()  # zero off the selected coords
                err = np.max(np.abs(got[idx] - np_buf[idx]))
                if ch.upload_codec.value_dtype == "f32":
                    assert err == 0.0
                else:
                    assert float(err) <= amax / 127

            # per-upload wire bytes off the unified telemetry surface (the
            # same counters the controller registry exposes; the assert
            # keeps them consistent with the envelope itself)
            tm = ch.telemetry
            per_upload = (tm.value("channel.upload_bytes")
                          // tm.value("channel.upload_messages"))
            assert per_upload == int(env.payload.nbytes)
            arms[name] = (bench(roundtrip, warmup=1, iters=iters, block=False),
                          int(per_upload))
        t_raw, b_raw = arms["raw"]
        t_int8, b_int8 = arms["int8"]
        saving = b_raw / b_int8
        row = {
            "bench": "upload", "p": p,
            "raw_s": t_raw, "int8_s": t_int8,
            "raw_bytes": b_raw, "int8_bytes": b_int8,
            "uplink_saving": saving,
        }
        sparse_bits = []
        for name in arms:
            if not name.startswith("topk"):
                continue
            t_k, b_k = arms[name]
            row[f"{name}_s"] = t_k
            row[f"{name}_bytes"] = b_k
            row[f"{name}_vs_raw"] = b_raw / b_k
            row[f"{name}_vs_int8"] = b_int8 / b_k
            sparse_bits.append(
                f"{name}={t_k*1e3:.2f}ms/{b_k/1e6:.3f}MB"
                f"({b_raw/b_k:.0f}x raw)"
            )
        # The headline sparse contract at k = P/64 (bytes, deterministic).
        assert row["topk_p64_vs_raw"] >= 8.0
        assert row["topk_p64_vs_int8"] >= 2.0
        rows.append(row)
        print(
            f"upload,P={p},"
            f"raw={t_raw*1e3:.2f}ms/{b_raw/1e6:.2f}MB,"
            f"int8={t_int8*1e3:.2f}ms/{b_int8/1e6:.2f}MB,"
            + ",".join(sparse_bits) +
            f",uplink_saving={saving:.2f}x",
            flush=True,
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--upload", action="store_true",
                    help="run only the uplink codec arms "
                         "(raw vs int8 vs top-k sparse)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump result rows as JSON")
    args = ap.parse_args(argv)

    if args.upload:
        rows = (run_upload(sizes=(2**16,), iters=2)
                if args.smoke else run_upload())
    elif args.smoke:
        rows = run(sizes=("100k",)) + run_broadcast(sizes=("100k",),
                                                    n_recipients=8, iters=2)
    else:
        rows = run() + run_broadcast()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == "__main__":
    main()
